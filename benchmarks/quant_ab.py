"""Representation A/B: fp32-ref vs fp32-fused vs bf16 vs int8 per
algorithm x batch bucket — the repo's analogue of the paper's FP-backend
study (§5.2, Figs. 9-11), with the quantized tier as the rung below
bf16/fp32.

For every estimator the sweep fits once per arm on the same blob problem
(fits are deterministic, so all arms share the fitted model), jits the
arm's ``predict_batch_fn`` and reports warm per-query latency plus the
label-agreement-vs-fp32 column — the accuracy axis the paper reports
alongside every representation change.  Results accumulate in
BENCH_quant.json via benchmarks/report.py.

The acceptance row: the int8 fused distance arm (kNN) must beat the fp32
fused arm at the largest bucket — int8 tiles stream 4x more rows per VMEM
budget and the packed integer selection keys delete the tie-break
machinery from the top-k merge (kernels/quantized.py, DESIGN.md §8).
"""
from __future__ import annotations

import time

import numpy as np

ALGORITHMS = ("knn", "kmeans", "gnb", "gmm", "rf")
# arm label -> (PrecisionPolicy name, registry path override)
ARMS = (
    ("fp32-ref", "fp32", "ref"),
    ("fp32-fused", "fp32", None),      # registry-selected hot arm
    ("bf16", "bf16", None),
    ("int8", "int8", None),            # quantized estimator tier
)
BUCKETS = (32, 128, 512)
BUCKETS_QUICK = (16, 64)
# seed=1: non-degenerate fits (one K-Means centroid per blob) — see
# tests/test_estimator_conformance.py::test_int8_label_agreement_bound
SEED = 1


def _fit(algo, X, y, pname, path):
    from repro.core.estimator import make_fitted
    from repro.kernels.dispatch import get_policy
    return make_fitted(algo, X, y, n_groups=int(y.max()) + 1,
                       policy=get_policy(pname), path=path)


def _arm_path(algo: str, est, bucket: int) -> str:
    """Which executable path actually serves this arm at this shape."""
    if est.quantized:
        return "quant"
    from repro.kernels import dispatch
    return dispatch.resolve(
        algo, dispatch.HOT_OPS[algo], path=est.path,
        **dispatch.hot_shape_kw(algo, est.serve_cost_shape(),
                                bucket)).name


def _bench(fn, params, batch, iters: int) -> float:
    import jax
    jax.block_until_ready(fn(params, batch)[0])       # compile + warm
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(params, batch)[0])
        best = min(best, time.perf_counter() - t0)
    return best * 1e6 / batch.shape[0]                # us per query


def run(csv_rows: list, quick: bool = False):
    import jax
    import jax.numpy as jnp

    from repro.data.datasets import class_blobs

    n, d = (384, 16) if quick else (1024, 21)
    buckets = BUCKETS_QUICK if quick else BUCKETS
    iters = 2 if quick else 5
    n_eval = max(buckets)
    X, y = class_blobs(n=n + n_eval, d=d, seed=SEED)
    Xt, yt, Q = X[:n], y[:n], X[n:]

    results = []
    print("\n== Quant A/B (fp32-ref / fp32-fused / bf16 / int8) ==")
    print(f"{'algo':7s} {'arm':10s} {'bucket':>6s} {'path':6s} "
          f"{'us/query':>9s} {'agree':>6s}")
    for algo in ALGORITHMS:
        fns, agree = {}, {}
        for arm, pname, path in ARMS:
            est = _fit(algo, Xt, yt, pname, path)
            fns[arm] = (est, jax.jit(est.predict_batch_fn()))
        # label agreement vs the fp32 hot arm on the full eval set
        base_est, base_fn = fns["fp32-fused"]
        baseline_cls = base_fn(base_est.params, jnp.asarray(Q))[0]
        for arm, _, _ in ARMS:
            est, fn = fns[arm]
            cls = fn(est.params, jnp.asarray(Q))[0]
            agree[arm] = float(jnp.mean(cls == baseline_cls))
        for arm, pname, path in ARMS:
            est, fn = fns[arm]
            for bucket in buckets:
                batch = jnp.asarray(Q[:bucket])
                us_q = _bench(fn, est.params, batch, iters)
                pth = _arm_path(algo, est, bucket)
                rec = {"algorithm": algo, "arm": arm, "bucket": bucket,
                       "path": pth, "us_per_query": us_q,
                       "shape": est.serve_cost_shape(),
                       "label_agreement": agree[arm]}
                results.append(rec)
                print(f"{algo:7s} {arm:10s} {bucket:6d} {pth:6s} "
                      f"{us_q:9.1f} {agree[arm]:6.3f}")
                csv_rows.append(
                    (f"quant_ab/{algo}/{arm}/b{bucket}", us_q,
                     f"path={pth};agreement={agree[arm]:.3f}"))
        # the acceptance comparison, printed next to the data
        big = max(buckets)
        fused = next(r for r in results
                     if r["algorithm"] == algo and r["arm"] == "fp32-fused"
                     and r["bucket"] == big)
        q8 = next(r for r in results
                  if r["algorithm"] == algo and r["arm"] == "int8"
                  and r["bucket"] == big)
        print(f"{algo:7s} int8 vs fp32-fused @b{big}: "
              f"{fused['us_per_query'] / q8['us_per_query']:.2f}x")
    return results


if __name__ == "__main__":
    run([], quick=True)
