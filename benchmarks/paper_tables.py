"""The paper's measured numbers (Tables 2-3, Figures 9-11), used as
calibration/validation targets by the benchmark harness."""

# Table 2: single-core cycles per inference
TABLE2_CYCLES = {
    "libgcc": {"svm": 1.01e6, "lr": 1.04e6, "gnb": 22.1e6, "knn": 8.31e6,
               "kmeans": 265e6, "rf": 16.8e3},
    "rvfplib": {"svm": 594e3, "lr": 607e3, "gnb": 15.8e6, "knn": 4.38e6,
                "kmeans": 168e6, "rf": 12.4e3},
    "fpu": {"svm": 39.4e3, "lr": 40.5e3, "gnb": 778e3, "knn": 259e3,
            "kmeans": 8.72e6, "rf": 6.76e3},
}

# Table 3: measured 1-vs-8-core speedups (and the paper's Amdahl bounds)
TABLE3_SPEEDUP = {
    "libgcc": {"svm": 7.03, "lr": 7.07, "gnb": 7.49, "knn": 7.59,
               "kmeans": 7.47, "rf": 6.66},
    "rvfplib": {"svm": 6.83, "lr": 6.83, "gnb": 7.64, "knn": 7.51,
                "kmeans": 7.29, "rf": 6.70},
    "fpu": {"svm": 7.05, "lr": 6.63, "gnb": 6.56, "knn": 6.65,
            "kmeans": 6.98, "rf": 6.82},
}
TABLE3_THEORETICAL = {
    "libgcc": {"svm": 7.94, "lr": 7.88, "gnb": 7.89, "knn": 7.94,
               "kmeans": 8.0, "rf": 7.92},
    "rvfplib": {"svm": 7.94, "lr": 7.95, "gnb": 7.96, "knn": 7.93,
                "kmeans": 8.0, "rf": 7.90},
    "fpu": {"svm": 7.83, "lr": 7.88, "gnb": 7.91, "knn": 7.59,
            "kmeans": 8.0, "rf": 7.81},
}

# Headline claims (abstract / §5)
HEADLINE = {
    "rvfplib_avg_speedup": 1.61,          # vs libgcc, single core
    "fpu_max_speedup": 32.09,             # vs libgcc, single core (kNN)
    "parallel_speedup_range": (6.56, 7.64),
    "m4_sequential_range": (1.36, 2.39),  # PULP-OPEN 1-core vs Cortex-M4
    "m4_parallel_range": (9.27, 15.85),   # PULP-OPEN 8-core vs Cortex-M4
}

# Fig. 11 per-kernel M4 comparisons (PULP-OPEN speedup over Cortex-M4)
FIG11_M4 = {
    "sequential": {"svm": 2.39, "lr": 2.30, "gnb": 1.74, "knn": 1.94,
                   "kmeans": 1.94, "rf": 1.36},
    "parallel": {"svm": 15.85, "lr": 14.65, "gnb": 11.43, "knn": 12.87,
                 "kmeans": 13.47, "rf": 9.27},
}
