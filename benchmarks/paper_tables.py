"""The paper's measured numbers (Tables 2-3, Figures 9-11), used as
calibration/validation targets by the benchmark harness."""

# Table 2: single-core cycles per inference
TABLE2_CYCLES = {
    "libgcc": {"svm": 1.01e6, "lr": 1.04e6, "gnb": 22.1e6, "knn": 8.31e6,
               "kmeans": 265e6, "rf": 16.8e3},
    "rvfplib": {"svm": 594e3, "lr": 607e3, "gnb": 15.8e6, "knn": 4.38e6,
                "kmeans": 168e6, "rf": 12.4e3},
    "fpu": {"svm": 39.4e3, "lr": 40.5e3, "gnb": 778e3, "knn": 259e3,
            "kmeans": 8.72e6, "rf": 6.76e3},
}

# Table 3: measured 1-vs-8-core speedups (and the paper's Amdahl bounds)
TABLE3_SPEEDUP = {
    "libgcc": {"svm": 7.03, "lr": 7.07, "gnb": 7.49, "knn": 7.59,
               "kmeans": 7.47, "rf": 6.66},
    "rvfplib": {"svm": 6.83, "lr": 6.83, "gnb": 7.64, "knn": 7.51,
                "kmeans": 7.29, "rf": 6.70},
    "fpu": {"svm": 7.05, "lr": 6.63, "gnb": 6.56, "knn": 6.65,
            "kmeans": 6.98, "rf": 6.82},
}
TABLE3_THEORETICAL = {
    "libgcc": {"svm": 7.94, "lr": 7.88, "gnb": 7.89, "knn": 7.94,
               "kmeans": 8.0, "rf": 7.92},
    "rvfplib": {"svm": 7.94, "lr": 7.95, "gnb": 7.96, "knn": 7.93,
                "kmeans": 8.0, "rf": 7.90},
    "fpu": {"svm": 7.83, "lr": 7.88, "gnb": 7.91, "knn": 7.59,
            "kmeans": 8.0, "rf": 7.81},
}

# Headline claims (abstract / §5)
HEADLINE = {
    "rvfplib_avg_speedup": 1.61,          # vs libgcc, single core
    "fpu_max_speedup": 32.09,             # vs libgcc, single core (kNN)
    "parallel_speedup_range": (6.56, 7.64),
    "m4_sequential_range": (1.36, 2.39),  # PULP-OPEN 1-core vs Cortex-M4
    "m4_parallel_range": (9.27, 15.85),   # PULP-OPEN 8-core vs Cortex-M4
}

# Fig. 11 per-kernel M4 comparisons (PULP-OPEN speedup over Cortex-M4)
FIG11_M4 = {
    "sequential": {"svm": 2.39, "lr": 2.30, "gnb": 1.74, "knn": 1.94,
                   "kmeans": 1.94, "rf": 1.36},
    "parallel": {"svm": 15.85, "lr": 14.65, "gnb": 11.43, "knn": 12.87,
                 "kmeans": 13.47, "rf": 9.27},
}

# Energy model for the unified backend-rung table (fp_backends.py).
# pj_per_cycle are DATASHEET-CLASS order-of-magnitude seeds, not
# measurements: PULP-class cores (GAP8/Mr.Wolf lineage the paper targets)
# sit around 5-15 pJ/cycle at their low-voltage operating point; a
# mainstream Cortex-M4 MCU (STM32F4-class at 3.3 V) is an order of
# magnitude hungrier per cycle; the FPU rung pays a small datapath
# premium over soft-float on the same core; the int8 tier rides an
# integer datapath that skips the FP unit entirely.  clk_mhz converts
# analytic cycles to latency for the rung table — the paper's PULP-OPEN
# fabric controller class clock vs a typical M4 part.
BACKEND_ENERGY = {
    "libgcc":    {"pj_per_cycle": 10.0, "clk_mhz": 50.0},
    "rvfplib":   {"pj_per_cycle": 10.0, "clk_mhz": 50.0},
    "fpu":       {"pj_per_cycle": 12.0, "clk_mhz": 50.0},
    "cortex-m4": {"pj_per_cycle": 100.0, "clk_mhz": 80.0},
    # measured tiers (CALIBRATION.json) are charged at the rate of the
    # analytic rung they functionally correspond to: fp32 tiers ride the
    # FPU rung, int8 the integer datapath
    "fp32-ref":  {"pj_per_cycle": 12.0, "clk_mhz": 50.0},
    "fused":     {"pj_per_cycle": 12.0, "clk_mhz": 50.0},
    "bf16":      {"pj_per_cycle": 10.0, "clk_mhz": 50.0},
    "int8":      {"pj_per_cycle": 8.0, "clk_mhz": 50.0},
    "grouped":   {"pj_per_cycle": 12.0, "clk_mhz": 50.0},
}
