"""ANN recall@k-vs-latency sweep: IVF-PQ (``--algo ann``) against the
exact fused kNN oracle, with nprobe as the knob (DESIGN.md §10).

For each reference size N the sweep fits exact kNN (arm ``exact`` — the
recall oracle AND the latency baseline) and one IVF-PQ index (arm
``ivfpq``: dsub=1 codebooks, int8 ADC shortlist + exact refine of the
top ``REFINE`` survivors), then walks the nprobe curve: per-query warm
latency per bucket plus recall@k of the returned neighbour ids against
the oracle's.  Results accumulate in BENCH_ann.json via
benchmarks/report.py (schema kind "ann").

The acceptance row (ISSUE 7): at the largest N some nprobe must hold
recall@10 >= 0.95 at >= 5x lower us/query than exact at the same
bucket.  The data is the many-blob regime (N//1024 clusters) — IVF
exploits local cluster structure, which real embedding corpora have and
an isotropic single-blob Gaussian pointedly lacks; the DESIGN.md §10
table records the flat-data ablation.
"""
from __future__ import annotations

import time

import numpy as np

SIZES = (4096, 65536, 262144)
SIZES_QUICK = (4096,)
BUCKETS = (64, 256)
BUCKETS_QUICK = (32,)
NPROBES = (1, 2, 4, 8, 16)
NPROBES_QUICK = (1, 2, 4, 8)
K = 10           # recall@10 is the acceptance metric
REFINE = 128     # exact re-rank depth of the ADC shortlist
SEED = 1


def _n_class(n: int) -> int:
    return max(16, min(256, n // 1024))


def _n_cells(n: int) -> int:
    return max(16, min(256, round(n ** 0.5)))


def _bench(fn, params, batch, iters: int) -> float:
    import jax
    jax.block_until_ready(fn(params, batch)[0])       # compile + warm
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(params, batch)[0])
        best = min(best, time.perf_counter() - t0)
    return best * 1e6 / batch.shape[0]                # us per query


def run(csv_rows: list, quick: bool = False):
    import jax
    import jax.numpy as jnp

    from repro.core.estimator import make_estimator, make_fitted
    from repro.data.datasets import class_blobs
    from repro.kernels import dispatch

    sizes = SIZES_QUICK if quick else SIZES
    buckets = BUCKETS_QUICK if quick else BUCKETS
    nprobes = NPROBES_QUICK if quick else NPROBES
    iters = 2 if quick else 3
    refine = 64 if quick else REFINE
    train_iters = 5 if quick else 10
    d, n_eval = 21, max(256, max(buckets))

    results = []
    print("\n== ANN sweep (IVF-PQ vs exact fused kNN, recall@10) ==")
    print(f"{'arm':6s} {'N':>7s} {'bucket':>6s} {'nprobe':>6s} "
          f"{'us/query':>9s} {'recall':>6s} {'vs exact':>8s}")
    for n in sizes:
        nc = _n_class(n)
        X, y = class_blobs(n=n + n_eval, d=d, n_class=nc, seed=SEED)
        Xt, yt, Q = X[:n], y[:n], X[n:]

        exact = make_fitted("knn", Xt, yt, n_groups=nc, k=K)
        exact_fn = jax.jit(exact.predict_batch_fn())
        _, oracle = dispatch.distance_topk(jnp.asarray(Xt),
                                           jnp.asarray(Q), K)
        oracle = np.asarray(oracle)
        exact_us = {}
        for bucket in buckets:
            us = _bench(exact_fn, exact.params, jnp.asarray(Q[:bucket]),
                        iters)
            exact_us[bucket] = us
            results.append({"algorithm": "ann", "arm": "exact",
                            "bucket": bucket, "N": n, "nprobe": 0,
                            "refine": 0, "us_per_query": us,
                            "recall_at_k": 1.0, "k": K})
            print(f"{'exact':6s} {n:7d} {bucket:6d} {0:6d} {us:9.1f} "
                  f"{1.0:6.3f} {'1.0x':>8s}")
            csv_rows.append((f"ann_sweep/exact/N{n}/b{bucket}", us,
                             "recall=1.000"))

        # one deterministic fit; the nprobe sweep re-serves the SAME
        # index (nprobe is a serve-time knob, not a fit-time one)
        ann = make_fitted("ann", Xt, yt, n_groups=nc, k=K,
                          n_cells=_n_cells(n), pq_m=d, refine=refine,
                          nprobe=max(nprobes), train_iters=train_iters)
        for nprobe in nprobes:
            est = make_estimator("ann", k=K, nprobe=nprobe, refine=refine)
            est._params = ann.params
            fn = jax.jit(est.predict_batch_fn())
            _, nbr = fn(ann.params, jnp.asarray(Q))
            nbr = np.asarray(nbr)
            recall = float(np.mean([
                len(set(nbr[i]) & set(oracle[i])) / K
                for i in range(Q.shape[0])]))
            for bucket in buckets:
                us = _bench(fn, ann.params, jnp.asarray(Q[:bucket]),
                            iters)
                results.append({"algorithm": "ann", "arm": "ivfpq",
                                "bucket": bucket, "N": n,
                                "nprobe": nprobe, "refine": refine,
                                "us_per_query": us, "recall_at_k": recall,
                                "k": K})
                ratio = exact_us[bucket] / us
                print(f"{'ivfpq':6s} {n:7d} {bucket:6d} {nprobe:6d} "
                      f"{us:9.1f} {recall:6.3f} {ratio:7.1f}x")
                csv_rows.append(
                    (f"ann_sweep/ivfpq/N{n}/b{bucket}/p{nprobe}", us,
                     f"recall={recall:.3f};vs_exact={ratio:.1f}x"))
    return results


if __name__ == "__main__":
    run([], quick=True)
