"""Algorithm × backend × batch-bucket sweep through the unified Estimator
API and ``NonNeuralServeEngine`` — the serving-side image of the paper's
"one library, many kernels, three FP backends" claim (§3.4, Figs. 9–11).

For every registered estimator (kNN, K-Means, GNB, GMM, RF) the sweep:

  * fits once on a synthetic blob problem,
  * serves each power-of-two bucket through the engine and reports warm
    per-query latency (wall-clock on whatever substrate runs this —
    TPU Mosaic or CPU interpret),
  * records which registry path ``kernels/dispatch.py`` selected for the
    hot op, and
  * attaches the analytic cycle model for the paper's three FP backends
    (libgcc / rvfplib / fpu via ``PrecisionPolicy.estimated_cycles``),
    since a TPU cannot *measure* soft-float emulation (DESIGN.md §6).

Results accumulate in BENCH_estimators.json via benchmarks/report.py.
"""
from __future__ import annotations

import time

import numpy as np

ALGORITHMS = ("knn", "kmeans", "gnb", "gmm", "rf")
COST_BACKENDS = ("libgcc", "rvfplib", "fpu")
BUCKETS = (8, 32, 128)
BUCKETS_QUICK = (8, 32)
POLICY_NAMES = ("fp32", "bf16")
POLICY_NAMES_QUICK = ("fp32",)


def _fit(algo: str, X, y, policy):
    from repro.core.estimator import make_fitted
    return make_fitted(algo, X, y, n_groups=int(y.max()) + 1, policy=policy)


def _hot_path(algo: str, est, bucket: int) -> str:
    """Which registry arm serves this (algorithm, shape)."""
    from repro.kernels import dispatch
    return dispatch.resolve(
        algo, dispatch.HOT_OPS[algo],
        **dispatch.hot_shape_kw(algo, est.serve_cost_shape(),
                                bucket)).name


def _bench_bucket(engine, X, bucket: int, iters: int) -> float:
    import jax
    batch = X[:bucket]
    if batch.shape[0] < bucket:
        batch = np.concatenate([batch] * (bucket // batch.shape[0] + 1))
        batch = batch[:bucket]
    res = engine.classify(batch)               # warm-up / compile
    jax.block_until_ready(res.classes)
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(engine.classify(batch).classes)
        best = min(best, time.perf_counter() - t0)
    return best * 1e6 / bucket                 # us per query


def run(csv_rows: list, quick: bool = False):
    """The acceptance sweep: every algorithm × policy × bucket through one
    serving engine class and one kernel registry."""
    from repro.kernels.dispatch import get_policy
    from repro.serving import NonNeuralServeEngine

    from repro.data.datasets import class_blobs

    n, d = (240, 16) if quick else (400, 21)
    buckets = BUCKETS_QUICK if quick else BUCKETS
    policies = POLICY_NAMES_QUICK if quick else POLICY_NAMES
    iters = 2 if quick else 5
    X, y = class_blobs(n=n, d=d)

    results = []
    print("\n== Estimator serving sweep (algorithm x backend x bucket) ==")
    print(f"{'algo':7s} {'policy':7s} {'bucket':>6s} {'path':8s} "
          f"{'us/query':>9s} {'cycles@libgcc':>14s} {'cycles@fpu':>11s}")
    for algo in ALGORITHMS:
        for pname in policies:
            policy = get_policy(pname)
            est = _fit(algo, X, y, policy)
            engine = NonNeuralServeEngine(est, max_batch=max(buckets))
            cycles = {b: policy.with_cost_backend(b).estimated_cycles(algo)
                      for b in COST_BACKENDS}
            for bucket in buckets:
                # profile-then-optimize (paper §5.2): micro-time every
                # registered arm for this bucket, then serve through the
                # measured winner — the sweep records both verdicts
                engine.warmup(np.zeros((bucket, d), np.float32),
                              autotune=True)
                arm = engine.tuned.get(engine._bucket(bucket))
                us_q = _bench_bucket(engine, X, bucket, iters)
                path = (arm.path or arm.static_path) if arm is not None \
                    else _hot_path(algo, est, bucket)
                rec = {"algorithm": algo, "policy": pname, "bucket": bucket,
                       "path": path, "us_per_query": us_q,
                       "shards": engine.n_shards,
                       "shape": est.serve_cost_shape(),
                       "analytic_cycles": cycles,
                       "tuned": None if arm is None else {
                           "strategy": arm.strategy, "path": arm.path,
                           "bn": arm.bn, "us": arm.us,
                           "static_path": arm.static_path,
                           "static_us": arm.static_us,
                           "differs": arm.differs}}
                results.append(rec)
                tag = "*" if arm is not None and arm.differs else " "
                print(f"{algo:7s} {pname:7s} {bucket:6d} {path:8s}{tag}"
                      f"{us_q:8.1f} {cycles['libgcc']:14.3e} "
                      f"{cycles['fpu']:11.3e}")
                csv_rows.append(
                    (f"estimator_serve/{algo}/{pname}/b{bucket}", us_q,
                     f"path={path};"
                     f"soft_float_penalty="
                     f"{cycles['libgcc'] / cycles['fpu']:.1f}x"))
            assert engine.bucket_launches, (algo, pname)
    return results


if __name__ == "__main__":
    run([], quick=True)
