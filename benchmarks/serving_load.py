"""Serving-load sweep: arrival rate x algorithm x bucket policy through
the micro-batching ``RequestScheduler`` (serving/scheduler.py).

Replays a seeded Poisson-ish arrival trace per cell and records the SLO
accounting — tail latency in drain ticks (deterministic for a seed),
throughput, bucket occupancy (the paper-§5.3 core-utilization analogue:
a half-empty bucket wastes silicon the way a stalled PULP core does),
cache hit-rate, and deadline-miss rate.  The bucket-policy axis is
``max_wait``: a short coalescing window trades occupancy (smaller,
emptier buckets) for tail latency, exactly the latency/energy knob the
paper's near-sensor framing cares about.

Results accumulate in BENCH_serving.json via benchmarks/report.py
(schema-checked on load and append like the other BENCH files).

  PYTHONPATH=src python -m benchmarks.serving_load [--quick]
"""
from __future__ import annotations

import sys
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

ALGORITHMS = ("knn", "kmeans", "gnb", "gmm", "rf")
ALGORITHMS_QUICK = ("knn", "gnb")
RATES = (1.0, 4.0, 16.0)
RATES_QUICK = (2.0, 8.0)
MAX_WAITS = (1, 4)            # bucket policy: latency- vs occupancy-leaning
MAX_WAITS_QUICK = (2,)
TICKS, TICKS_QUICK = 96, 32
DEADLINE_FACTOR = 2           # SLO = 2x the coalescing window


def run(csv_rows: list, quick: bool = False):
    from repro.core.estimator import make_fitted
    from repro.data.datasets import class_blobs
    from repro.serving import (NonNeuralServeEngine, RequestScheduler,
                               poisson_trace, replay_trace)

    algos = ALGORITHMS_QUICK if quick else ALGORITHMS
    rates = RATES_QUICK if quick else RATES
    waits = MAX_WAITS_QUICK if quick else MAX_WAITS
    ticks = TICKS_QUICK if quick else TICKS
    n, d = (160, 12) if quick else (320, 21)
    max_batch = 32

    X, y = class_blobs(n=n, d=d)
    # repeated-query traffic: cycle a pool smaller than the LRU so the
    # cache axis actually shows up in hit_rate
    Q = X[:48]
    results = []
    print("\n== Serving load sweep (rate x algorithm x bucket policy) ==")
    print(f"{'algo':7s} {'rate':>5s} {'wait':>4s} {'p50':>4s} {'p95':>4s} "
          f"{'p99':>4s} {'req/tick':>8s} {'occ':>5s} {'hit':>5s} "
          f"{'miss':>5s}")
    for algo in algos:
        est = make_fitted(algo, X, y, n_groups=int(y.max()) + 1)
        # one engine per algorithm: buckets compile once, every
        # (rate, max_wait) cell reuses them (a fresh scheduler per cell
        # resets the stats; bucket_launches accumulates across cells)
        engine = NonNeuralServeEngine(est, max_batch=max_batch)
        engine.warmup_buckets(d)
        for max_wait in waits:
            for rate in rates:
                sched = RequestScheduler(engine, max_wait=max_wait,
                                         cache_size=64)
                counts = poisson_trace(rate, ticks, seed=0)
                replay_trace(sched, Q, counts,
                             deadline=DEADLINE_FACTOR * max_wait)
                assert set(engine.bucket_launches) <= sched.warmed, \
                    (algo, rate, max_wait)   # no mid-stream compiles
                s = sched.stats.summary()
                rec = {"algorithm": algo, "rate": rate,
                       "max_wait": max_wait, "ticks": ticks,
                       "completed": s["completed"],
                       "p50": s["p50"], "p95": s["p95"], "p99": s["p99"],
                       "throughput": s["throughput"],
                       "occupancy": s["occupancy"],
                       "hit_rate": s["hit_rate"],
                       "deadline_miss_rate": s["deadline_miss_rate"]}
                results.append(rec)
                print(f"{algo:7s} {rate:5.1f} {max_wait:4d} {s['p50']:4.0f} "
                      f"{s['p95']:4.0f} {s['p99']:4.0f} "
                      f"{s['throughput']:8.2f} {s['occupancy']:5.2f} "
                      f"{s['hit_rate']:5.2f} "
                      f"{s['deadline_miss_rate']:5.2f}")
                mean_batch_us = 1e6 * float(np.mean(
                    sched.stats.batch_times)) if sched.stats.launches else 0.0
                csv_rows.append(
                    (f"serving_load/{algo}/r{rate:g}/w{max_wait}",
                     mean_batch_us,
                     f"p95_ticks={s['p95']:.0f};occ={s['occupancy']:.2f};"
                     f"hit={s['hit_rate']:.2f}"))
    return results


if __name__ == "__main__":
    import argparse

    from benchmarks import report

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    report.write_serving_entry(run([], quick=args.quick))
    print("\n### Serving load\n")
    print(report.serving_table())
