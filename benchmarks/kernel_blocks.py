"""Pallas BlockSpec analysis: VMEM working set + MXU alignment per kernel.

No wall-clock on CPU — this is the structural reasoning the dry-run perf
loop uses for kernels (assignment: "BlockSpec shapes determine the VMEM
footprint you claim; pick them so the working set fits VMEM and the MXU
matmul dims are multiples of 128").
"""
from __future__ import annotations

VMEM_BYTES = 16 * 2 ** 20   # ~16 MiB/core budget (conservative)
MXU = 128


def gemm_working_set(bm: int, bn: int, bk: int, bytes_in: int = 2,
                     acc_bytes: int = 4) -> dict:
    """Double-buffered input tiles + f32 accumulator."""
    a = bm * bk * bytes_in * 2         # 2x: grid pipeline double buffering
    b = bk * bn * bytes_in * 2
    acc = bm * bn * acc_bytes
    out = bm * bn * bytes_in
    total = a + b + acc + out
    return {
        "tiles": f"A({bm}x{bk}) B({bk}x{bn}) acc({bm}x{bn})",
        "vmem_bytes": total,
        "fits": total <= VMEM_BYTES,
        "mxu_aligned": bm % MXU == 0 and bn % MXU == 0 and bk % MXU == 0,
        "arith_intensity": (2 * bm * bn * bk) /
                           ((bm * bk + bk * bn) * bytes_in + bm * bn * bytes_in),
    }


def fused_topk_working_set(bn: int, d: int, q: int, k: int) -> dict:
    """VMEM footprint of one fused distance->top-k grid step — the TPU
    image of the paper's L1-resident e.  Byte count comes from the
    autotuner's own formula (ops.fused_topk_working_set_bytes) so this
    table can never disagree with what the kernel wrapper picks."""
    from repro.kernels.dispatch import fused_topk_working_set_bytes
    total = fused_topk_working_set_bytes(bn, d, q, k)
    return {
        "tiles": f"A({bn}x{d}) C({q}x{d}) e({bn}x{q}) acc({q}x{k})",
        "vmem_bytes": total,
        "fits": total <= VMEM_BYTES,
        "sublane_aligned": bn % 8 == 0,
    }


def topk_bytes_moved(n: int, d: int, q: int, k: int,
                     bytes_in: int = 4) -> dict:
    """Analytic HBM traffic for the kNN hot path, both schedules.

    two-pass: read A + C, WRITE the (N, Q) e matrix, then READ it back for
    the selection kernel, write (Q, k) x2 outputs.
    fused:    read A + C once, write (Q, k) x2 — e never leaves VMEM.
    """
    inputs = n * d * bytes_in + q * d * bytes_in
    outputs = q * k * (4 + 4)
    e = n * q * 4
    two_pass = inputs + 2 * e + outputs
    fused = inputs + outputs
    return {"two_pass": two_pass, "fused": fused,
            "saved": 2 * e, "ratio": fused / two_pass}


def flash_working_set(bq: int, bk: int, d: int, bytes_in: int = 2) -> dict:
    q = bq * d * bytes_in
    kv = 2 * bk * d * bytes_in * 2
    s = bq * bk * 4
    stats = bq * (2 + d) * 4
    total = q + kv + s + stats
    return {"vmem_bytes": total, "fits": total <= VMEM_BYTES,
            "mxu_aligned": bq % MXU == 0 and bk % MXU == 0}


def run(csv_rows: list):
    print("\n== Kernel BlockSpec analysis (VMEM budget 16 MiB, MXU 128) ==")
    print(f"{'kernel':8s} {'blocks':26s} {'VMEM':>10s} {'fits':>5s} "
          f"{'aligned':>8s} {'AI (flop/B)':>12s}")
    best = None
    for bm, bn, bk in [(128, 128, 128), (256, 256, 256), (512, 512, 256),
                       (512, 1024, 512), (1024, 1024, 512)]:
        w = gemm_working_set(bm, bn, bk)
        print(f"{'gemm':8s} {w['tiles']:26s} {w['vmem_bytes']/2**20:9.2f}M "
              f"{str(w['fits']):>5s} {str(w['mxu_aligned']):>8s} "
              f"{w['arith_intensity']:12.1f}")
        if w["fits"] and w["mxu_aligned"]:
            best = (bm, bn, bk, w["arith_intensity"])
    print(f"-- best fitting gemm tile: {best[:3]}, arithmetic intensity "
          f"{best[3]:.0f} flop/B (ridge point at 197e12/819e9 = 241)")
    for bq, bk in [(128, 128), (256, 512), (512, 1024)]:
        w = flash_working_set(bq, bk, 128)
        print(f"{'flash':8s} bq={bq} bk={bk} d=128{'':11s}"
              f"{w['vmem_bytes']/2**20:9.2f}M {str(w['fits']):>5s} "
              f"{str(w['mxu_aligned']):>8s}")
    best_bn = None
    for bn in [128, 256, 512, 1024, 2048]:
        w = fused_topk_working_set(bn, 64, 16, 8)
        print(f"{'dtopk':8s} {w['tiles']:26s} {w['vmem_bytes']/2**20:9.2f}M "
              f"{str(w['fits']):>5s} {str(w['sublane_aligned']):>8s}")
        if w["fits"] and w["sublane_aligned"]:
            best_bn = bn
    print("-- fused distance->top-k HBM traffic vs two-pass "
          "(N x d=64, Q=16, k=8):")
    for n in [4096, 65536, 1048576]:
        b = topk_bytes_moved(n, 64, 16, 8)
        print(f"   N={n:>8d}: two_pass={b['two_pass']/2**20:8.2f}M "
              f"fused={b['fused']/2**20:8.2f}M "
              f"(saves {b['saved']/2**20:.2f}M, ratio {b['ratio']:.2f})")
    csv_rows.append(("kernel_blocks/gemm_best", 0.0,
                     f"tile={best[:3]};ai={best[3]:.0f}"))
    csv_rows.append(("kernel_blocks/fused_topk_best_bn", 0.0,
                     f"bn={best_bn};bytes_ratio_1M="
                     f"{topk_bytes_moved(1048576, 64, 16, 8)['ratio']:.3f}"))


if __name__ == "__main__":
    run([])
